"""Scaled (row-compact, shardable) sparse RTRL: exactness + invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bptt, cells
from repro.core import scaled_rtrl as SR


def _setup(n=48, n_in=12, B=3, capacity=1.0, sparsity=0.8, seed=0):
    cfg = SR.ScaledRTRLConfig(n=n, n_in=n_in, batch=B,
                              beta_capacity=capacity, sparsity=sparsity)
    params, masks = SR.init_params(cfg, jax.random.key(seed))
    return cfg, params, masks


def test_compact_step_equals_dense_step():
    cfg, params, _ = _setup()
    w = cells.rec_param_tree(params)
    xs = jax.random.normal(jax.random.key(1), (6, cfg.batch, cfg.n_in))
    state = SR.init_state(cfg)
    a = jnp.zeros((cfg.batch, cfg.n))
    M = jnp.zeros((cfg.batch, cfg.n, cfg.n, cfg.m))
    for t in range(6):
        state, ov = SR.compact_step(cfg, w, state, xs[t])
        a, M = SR.dense_step(cfg, w, a, M, xs[t])
        assert int(ov.max()) == 0
    np.testing.assert_array_equal(np.asarray(state["a"]), np.asarray(a))
    np.testing.assert_allclose(
        np.asarray(SR.compact_to_dense_M(cfg, state)), np.asarray(M),
        atol=1e-6)


def test_scaled_rtrl_grads_match_bptt():
    cfg, params, _ = _setup()
    xs = jax.random.normal(jax.random.key(2), (8, cfg.batch, cfg.n_in))
    labels = jnp.arange(cfg.batch) % cfg.n_out
    loss_c, grads_c, stats = SR.rtrl_grads(cfg, params, xs, labels)
    assert int(stats["overflow"].max()) == 0
    loss_b, grads_b, _ = bptt.bptt_loss_and_grads(cfg.cell_cfg(), params,
                                                  xs, labels)
    assert abs(float(loss_c - loss_b)) < 1e-5
    for gc, gb in zip(jax.tree.leaves(grads_c), jax.tree.leaves(grads_b)):
        np.testing.assert_allclose(np.asarray(gc), np.asarray(gb),
                                   atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("sparsity", [0.5, 0.9])
def test_scaled_rtrl_col_compact_matches_bptt(sparsity):
    """Dual (row x column) compact carry == BPTT on the surviving params;
    the carried width shrinks to Pc_pad ~= w~ P_pad."""
    from repro.core import sparse_rtrl as SP
    cfg, params, masks = _setup(sparsity=sparsity)
    cl = cfg.col_layout(masks)
    assert cl.Pc_pad < cfg.layout().P_pad
    assert cl.Pc == int(np.asarray(
        SP.flat_col_mask(cfg.layout(), masks)).sum())
    xs = jax.random.normal(jax.random.key(2), (8, cfg.batch, cfg.n_in))
    labels = jnp.arange(cfg.batch) % cfg.n_out
    loss_c, grads_c, stats = SR.rtrl_grads(cfg, params, xs, labels, masks)
    assert int(stats["overflow"].max()) == 0
    assert jax.eval_shape(lambda: SR.init_state(cfg, cl))["vals"].shape[-1] \
        == cl.Pc_pad
    loss_b, grads_b, _ = bptt.bptt_loss_and_grads(cfg.cell_cfg(), params,
                                                  xs, labels)
    assert abs(float(loss_c - loss_b)) < 1e-5
    gc = SP.apply_masks(grads_c, masks)
    gb = SP.apply_masks(grads_b, masks)
    for a, b in zip(jax.tree.leaves(gc), jax.tree.leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_capacity_is_static_and_memory_beta_scaled():
    cfg = SR.ScaledRTRLConfig(n=1024, beta_capacity=0.25)
    st = jax.eval_shape(lambda: SR.init_state(cfg))
    assert st["vals"].shape[1] == cfg.K
    assert cfg.K <= 0.27 * cfg.n    # memory = beta~ * n p, not n p


def test_stacked_scaled_rtrl_grads_match_bptt():
    """Depth path: n_layers=2 compact carry == stacked BPTT on surviving
    params (masked per layer)."""
    from repro.core import bptt, stacked_rtrl as ST
    cfg = SR.ScaledRTRLConfig(n=32, n_in=8, batch=3, n_layers=2,
                              beta_capacity=1.0, sparsity=0.8)
    params, masks = SR.init_params(cfg, jax.random.key(0))
    xs = jax.random.normal(jax.random.key(2), (6, cfg.batch, cfg.n_in))
    labels = jnp.arange(cfg.batch) % cfg.n_out
    loss_c, grads_c, stats = SR.rtrl_grads(cfg, params, xs, labels)
    assert int(stats["overflow"].max()) == 0
    loss_b, grads_b, _ = bptt.stacked_bptt_loss_and_grads(
        cfg.stacked_cfg(), params, xs, labels)
    assert abs(float(loss_c - loss_b)) < 1e-5
    gc = ST.apply_stacked_masks(grads_c, masks)
    gb = ST.apply_stacked_masks(grads_b, masks)
    for a, b in zip(jax.tree.leaves(gc), jax.tree.leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("sparsity", [0.5, 0.9])
def test_stacked_scaled_col_compact_matches_bptt(sparsity):
    """Depth + dual compaction: every layer's carry at [B, K, Pc_pad] on the
    shared stacked compact axis == stacked BPTT on surviving params."""
    from repro.core import stacked_rtrl as ST
    cfg = SR.ScaledRTRLConfig(n=32, n_in=8, batch=3, n_layers=2,
                              beta_capacity=1.0, sparsity=sparsity)
    params, masks = SR.init_params(cfg, jax.random.key(0))
    cl = cfg.col_layout(masks)
    assert cl.Pc_pad < cfg.slayout().P_pad
    xs = jax.random.normal(jax.random.key(2), (6, cfg.batch, cfg.n_in))
    labels = jnp.arange(cfg.batch) % cfg.n_out
    loss_c, grads_c, stats = SR.rtrl_grads(cfg, params, xs, labels, masks)
    assert int(stats["overflow"].max()) == 0
    loss_b, grads_b, _ = bptt.stacked_bptt_loss_and_grads(
        cfg.stacked_cfg(), params, xs, labels)
    assert abs(float(loss_c - loss_b)) < 1e-5
    gc = ST.apply_stacked_masks(grads_c, masks)
    gb = ST.apply_stacked_masks(grads_b, masks)
    for a, b in zip(jax.tree.leaves(gc), jax.tree.leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_col_compact_sharded_step_no_collectives():
    """Dual-compact carry shards the COMPACT column axis to 'model' with
    zero collectives — the contraction still has no cross-column reduction,
    it is just w~ narrower per shard."""
    from repro.launch.mesh import make_host_mesh
    from repro.launch.costing import parse_collective_bytes
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_host_mesh()
    cfg, params, masks = _setup(n=32, sparsity=0.9)
    cl = cfg.col_layout(masks)
    state_sh, _ = SR.sharded_step_specs(cfg, mesh)
    rep = NamedSharding(mesh, P())

    def step(params, state, x):
        w = cells.rec_param_tree(params)
        return SR.compact_step(cfg, w, state, x, cl=cl)[0]

    params_abs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    st_abs = jax.eval_shape(lambda: SR.init_state(cfg, cl))
    x_abs = jax.ShapeDtypeStruct((cfg.batch, cfg.n_in), jnp.float32)
    compiled = jax.jit(step, in_shardings=(
        jax.tree.map(lambda _: rep, params_abs), state_sh,
        NamedSharding(mesh, P("data", None)))).lower(
        params_abs, st_abs, x_abs).compile()
    coll = parse_collective_bytes(compiled.as_text())
    assert sum(coll.values()) == 0, coll


def test_stacked_distributed_step_shards_without_collectives():
    """Layer blocks stay embarrassingly parallel along the parameter-column
    axis: the stacked influence update emits no collectives either."""
    from repro.launch.mesh import make_host_mesh
    from repro.launch.costing import parse_collective_bytes
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_host_mesh()
    cfg = SR.ScaledRTRLConfig(n=32, n_in=8, batch=4, n_layers=2,
                              beta_capacity=0.5, sparsity=0.8)
    params, _ = SR.init_params(cfg, jax.random.key(0))
    state_sh, _ = SR.sharded_step_specs(cfg, mesh)
    rep = NamedSharding(mesh, P())

    def step(params, state, x):
        return SR.compact_step(cfg, params["layers"], state, x)[0]

    params_abs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    st_abs = jax.eval_shape(lambda: SR.init_state(cfg))
    x_abs = jax.ShapeDtypeStruct((cfg.batch, cfg.n_in), jnp.float32)
    compiled = jax.jit(step, in_shardings=(
        jax.tree.map(lambda _: rep, params_abs), state_sh,
        NamedSharding(mesh, P("data", None)))).lower(
        params_abs, st_abs, x_abs).compile()
    coll = parse_collective_bytes(compiled.as_text())
    assert sum(coll.values()) == 0, coll


def test_stacked_flop_accounting_reduces_to_single_layer():
    """The (l, j)-block op model collapses to the paper's single-layer
    formulas at L=1 and is super-additive in depth."""
    from repro.core.costs import (influence_update_flops,
                                  stacked_influence_update_flops,
                                  stacked_savings_factor, savings_factor)
    n, P = 64, 1024
    acc1 = stacked_influence_update_flops([n], [P])
    assert acc1["dense"] == influence_update_flops(n, P)
    acc1s = stacked_influence_update_flops([n], [P], betas_t=[0.8],
                                           betas_prev=[0.5])
    K, Kp = 0.2 * n, 0.5 * n
    assert abs(acc1s["sparse"] - influence_update_flops(n, P, K, Kp)) < 1e-6
    assert abs(stacked_savings_factor([0.8], [0.5], [0.9])
               - savings_factor(0.8, 0.5, 0.9)) < 1e-12
    acc2 = stacked_influence_update_flops([n, n], [P, P])
    # L=2: blocks (0,0), (1,0)+cross, (1,1)+cross > 3x the L=1 J-term
    assert acc2["dense"] > 3 * acc1["dense"]
    assert set(acc2["blocks"]) == {(0, 0), (1, 0), (1, 1)}


def test_compact_flop_scaling():
    """FLOP count of the compact update scales as K^2 (beta~^2 n^2 p)."""
    def flops_for(capacity):
        from repro.launch.costing import cost_analysis_dict
        cfg, params, _ = _setup(n=64, capacity=capacity)
        w = cells.rec_param_tree(params)
        x = jnp.zeros((cfg.batch, cfg.n_in))
        st = SR.init_state(cfg)
        c = jax.jit(lambda s, x: SR.compact_step(cfg, w, s, x)[0]) \
            .lower(st, x).compile()
        return cost_analysis_dict(c).get("flops", 0.0), cfg.K

    f_full, k_full = flops_for(1.0)
    f_half, k_half = flops_for(0.5)
    ratio = f_half / f_full
    ideal = (k_half / k_full) ** 2
    assert ratio < 0.45, (ratio, ideal)   # ~beta~^2, some fixed overhead


def test_distributed_step_shards_without_collectives():
    """On a small host mesh: the influence update emits no collectives."""
    from repro.launch.mesh import make_host_mesh
    from repro.launch.costing import parse_collective_bytes
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_host_mesh()
    cfg, params, _ = _setup(n=32)
    state_sh, _ = SR.sharded_step_specs(cfg, mesh)
    rep = NamedSharding(mesh, P())

    def step(params, state, x):
        w = cells.rec_param_tree(params)
        return SR.compact_step(cfg, w, state, x)[0]

    params_abs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    st_abs = jax.eval_shape(lambda: SR.init_state(cfg))
    x_abs = jax.ShapeDtypeStruct((cfg.batch, cfg.n_in), jnp.float32)
    compiled = jax.jit(step, in_shardings=(
        jax.tree.map(lambda _: rep, params_abs), state_sh,
        NamedSharding(mesh, P("data", None)))).lower(
        params_abs, st_abs, x_abs).compile()
    coll = parse_collective_bytes(compiled.as_text())
    assert sum(coll.values()) == 0, coll
