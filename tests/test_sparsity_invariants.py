"""Property-based tests (hypothesis) for the paper's structural claims.

`hypothesis` is optional: when absent, each @given test is skipped and a
small deterministic fallback case at the bottom covers the same invariants.
"""
import importlib.util

import jax
import jax.numpy as jnp
import numpy as np
import pytest

if importlib.util.find_spec("hypothesis") is not None:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
else:
    HAVE_HYPOTHESIS = False

    def settings(**_kw):                      # no-op decorator factory
        return lambda f: f

    def given(**_kw):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    class _St:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()

from repro.core import cells, sparse_rtrl
from repro.core.cells import EGRUConfig
from repro.core.costs import savings_factor, tpu_block_factor


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 10_000), kind=st.sampled_from(["rnn", "gru"]),
       eps=st.floats(0.05, 0.6))
def test_influence_rows_zero_where_hp_zero(seed, kind, eps):
    """Eq. (10): beta(t) x n rows of M(t) are exactly zero."""
    cfg = EGRUConfig(n_hidden=8, n_in=3, kind=kind, eps=eps)
    key = jax.random.key(seed)
    params = cells.init_params(cfg, key)
    w = cells.rec_param_tree(params)
    a = (jax.random.uniform(jax.random.fold_in(key, 1), (4, 8)) > 0.5) * 1.0
    x = jax.random.normal(jax.random.fold_in(key, 2), (4, 3))
    a_new, hp, Jhat, mbar = sparse_rtrl.cell_partials(cfg, w, a, x)
    M_prev = sparse_rtrl.init_influence(cfg, 4)
    M_prev = jax.tree.map(
        lambda m: jax.random.normal(jax.random.fold_in(key, 3), m.shape), M_prev)
    M = sparse_rtrl.influence_update(cfg, M_prev, hp, Jhat, mbar)
    zero_rows = np.asarray(hp == 0.0)
    for g, Mg in M.items():
        flat = np.asarray(Mg).reshape(Mg.shape[0], Mg.shape[1], -1)
        assert np.all(flat[zero_rows] == 0.0), g


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 10_000), sparsity=st.floats(0.2, 0.95))
def test_masked_columns_stay_zero_forever(seed, sparsity):
    """Sec. 5: with a fixed mask, pruned parameters' M columns stay zero
    across timesteps (checked after several updates)."""
    cfg = EGRUConfig(n_hidden=8, n_in=3, kind="gru")
    key = jax.random.key(seed)
    params = cells.init_params(cfg, key)
    masks = sparse_rtrl.make_masks(cfg, jax.random.fold_in(key, 1), sparsity)
    params = sparse_rtrl.apply_masks(params, masks)
    w = cells.rec_param_tree(params)
    M = sparse_rtrl.init_influence(cfg, 2)
    a = cells.init_state(cfg, 2)
    for t in range(4):
        x = jax.random.normal(jax.random.fold_in(key, 10 + t), (2, 3))
        a, hp, Jhat, mbar = sparse_rtrl.cell_partials(cfg, w, a, x)
        M = sparse_rtrl.influence_update(cfg, M, hp, Jhat, mbar, masks)
    n, n_in = cfg.n_hidden, cfg.n_in
    for g in ("u", "r", "z"):
        gm = np.concatenate([np.asarray(masks[g]["W"]).T,
                             np.asarray(masks[g]["R"]).T,
                             np.ones((n, 1))], axis=1)     # [q, m]
        Mg = np.asarray(M[g])                              # [B, k, q, m]
        dead = gm == 0.0
        assert np.all(Mg[:, :, dead] == 0.0), g


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 10_000), sparsity=st.floats(0.0, 0.9))
def test_masked_optimizer_keeps_pruned_weights_zero(seed, sparsity):
    from repro.optim import make_optimizer
    from repro.optim.optimizers import masked
    cfg = EGRUConfig(n_hidden=8, n_in=3)
    key = jax.random.key(seed)
    params = cells.init_params(cfg, key)
    masks = sparse_rtrl.make_masks(cfg, jax.random.fold_in(key, 1), sparsity)
    params = sparse_rtrl.apply_masks(params, masks)
    opt = masked(make_optimizer("adamw", lr=1e-2), masks)
    state = opt.init(params)
    grads = jax.tree.map(lambda p: jnp.ones_like(p), params)
    for step in range(3):
        params, state = opt.update(grads, state, params, jnp.int32(step))
    for g in ("u", "r", "z"):
        for k in ("W", "R"):
            p = np.asarray(params[g][k])
            mk = np.asarray(masks[g][k])
            assert np.all(p[mk == 0.0] == 0.0)


@settings(deadline=None, max_examples=20)
@given(bt=st.floats(0.0, 1.0), bp=st.floats(0.0, 1.0), om=st.floats(0.0, 1.0))
def test_savings_factor_bounds(bt, bp, om):
    f = savings_factor(bt, bp, om)
    assert 0.0 <= f <= 1.0
    assert f <= savings_factor(0.0, 0.0, 0.0)


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 1000), sparsity=st.floats(0.3, 0.95),
       block=st.sampled_from([4, 8]))
def test_block_masks_have_full_block_structure(seed, sparsity, block):
    cfg = EGRUConfig(n_hidden=32, n_in=8)
    masks = sparse_rtrl.make_masks(cfg, jax.random.key(seed), sparsity,
                                   block=block)
    R = np.asarray(masks["u"]["R"])
    bf = tpu_block_factor(R, block=block)
    # every live block is fully dense -> block density == element density
    assert abs(bf - R.mean()) < 1e-6


@pytest.mark.parametrize("kind", ["rnn", "gru"])
def test_influence_rows_zero_where_hp_zero_fallback(kind):
    """Deterministic (non-hypothesis) cover of the Eq. (10) row invariant."""
    cfg = EGRUConfig(n_hidden=8, n_in=3, kind=kind, eps=0.3)
    key = jax.random.key(0)
    params = cells.init_params(cfg, key)
    w = cells.rec_param_tree(params)
    a = (jax.random.uniform(jax.random.fold_in(key, 1), (4, 8)) > 0.5) * 1.0
    x = jax.random.normal(jax.random.fold_in(key, 2), (4, 3))
    a_new, hp, Jhat, mbar = sparse_rtrl.cell_partials(cfg, w, a, x)
    M_prev = sparse_rtrl.init_influence(cfg, 4)
    M_prev = jax.tree.map(
        lambda m: jax.random.normal(jax.random.fold_in(key, 3), m.shape), M_prev)
    M = sparse_rtrl.influence_update(cfg, M_prev, hp, Jhat, mbar)
    zero_rows = np.asarray(hp == 0.0)
    assert zero_rows.any()          # eps=0.3 leaves some rows dead
    for g, Mg in M.items():
        flat = np.asarray(Mg).reshape(Mg.shape[0], Mg.shape[1], -1)
        assert np.all(flat[zero_rows] == 0.0), g


def test_masked_columns_stay_zero_fallback():
    """Deterministic cover of the Sec. 5 column invariant."""
    cfg = EGRUConfig(n_hidden=8, n_in=3, kind="gru")
    key = jax.random.key(7)
    params = cells.init_params(cfg, key)
    masks = sparse_rtrl.make_masks(cfg, jax.random.fold_in(key, 1), 0.7)
    params = sparse_rtrl.apply_masks(params, masks)
    w = cells.rec_param_tree(params)
    M = sparse_rtrl.init_influence(cfg, 2)
    a = cells.init_state(cfg, 2)
    for t in range(4):
        x = jax.random.normal(jax.random.fold_in(key, 10 + t), (2, 3))
        a, hp, Jhat, mbar = sparse_rtrl.cell_partials(cfg, w, a, x)
        M = sparse_rtrl.influence_update(cfg, M, hp, Jhat, mbar, masks)
    n = cfg.n_hidden
    for g in ("u", "r", "z"):
        gm = np.concatenate([np.asarray(masks[g]["W"]).T,
                             np.asarray(masks[g]["R"]).T,
                             np.ones((n, 1))], axis=1)
        dead = gm == 0.0
        assert dead.any()
        assert np.all(np.asarray(M[g])[:, :, dead] == 0.0), g


def test_omega_measurement():
    cfg = EGRUConfig(n_hidden=64, n_in=16)
    masks = sparse_rtrl.make_masks(cfg, jax.random.key(0), 0.8)
    om = float(sparse_rtrl.omega_tilde(masks))
    assert abs(om - 0.2) < 0.03
