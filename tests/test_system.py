"""End-to-end behaviour tests for the paper's system.

1. The paper's experiment end-to-end: EGRU-16 on spirals trained with exact
   sparse RTRL at 80% parameter sparsity reaches high accuracy, while
   measured activity/backward sparsity delivers real compute savings
   (compute-adjusted iterations << dense iterations).
2. The LM substrate end-to-end: a smoke decoder trains (loss drops) through
   the full jit'd train step, and the serving engine generates.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cells, sparse_rtrl
from repro.core.cells import EGRUConfig
from repro.core.costs import compute_adjusted_iterations
from repro.data.spiral import spiral_batches
from repro.optim import make_optimizer
from repro.optim.optimizers import masked


@pytest.mark.slow
def test_spiral_sparse_rtrl_end_to_end():
    cfg = EGRUConfig()                    # paper defaults (16 hidden, gru)
    params = cells.init_params(cfg, jax.random.key(0))
    masks = sparse_rtrl.make_masks(cfg, jax.random.key(1), sparsity=0.8)
    params = sparse_rtrl.apply_masks(params, masks)
    opt = masked(make_optimizer("adamw", lr=cfg.lr), masks)
    opt_state = jax.jit(opt.init)(params)

    @jax.jit
    def train_step(params, opt_state, xs, ys, step):
        loss, grads, stats = sparse_rtrl.sparse_rtrl_loss_and_grads(
            cfg, params, xs, ys, masks)
        params, opt_state = opt.update(grads, opt_state, params, step)
        return params, opt_state, loss, stats

    @jax.jit
    def eval_acc(params, xs, ys):
        logits_t, _ = cells.sequence_logits(cfg, params, xs)
        return cells.accuracy(logits_t.mean(0), ys)

    it = spiral_batches(cfg.batch_size, cfg.seq_len)
    betas = []
    for i in range(700):
        xs, ys = next(it)
        params, opt_state, loss, stats = train_step(
            params, opt_state, jnp.asarray(xs), jnp.asarray(ys), jnp.int32(i))
        betas.append(np.asarray(stats["beta"]))

    evx, evy = next(spiral_batches(512, cfg.seq_len, seed=99))
    acc = float(eval_acc(params, jnp.asarray(evx), jnp.asarray(evy)))
    assert acc > 0.9, acc

    betas = np.stack(betas)                       # [iters, T]
    cai = compute_adjusted_iterations(betas, np.roll(betas, 1, 1), omega=0.8)
    # paper's claim: with 80% parameter sparsity + activity sparsity, total
    # compute is a few % of dense RTRL for the same number of iterations
    assert cai[-1] < 0.08 * len(betas)
    # backward sparsity emerges during training (grows further past 700 iters)
    assert betas[-100:].mean() > 0.1


@pytest.mark.slow
def test_lm_substrate_end_to_end(tmp_path):
    from repro.configs import get_config, smoke_config
    from repro.configs.base import ShapeSuite
    from repro.data.tokens import synthetic_token_batches
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_host_mesh

    cfg = smoke_config(get_config("gemma2-2b"))
    mesh = make_host_mesh()
    shape = ShapeSuite("t", 32, 4, "train")
    built = steps_lib.make_train_step(cfg, mesh, shape)
    from repro.models import get_model
    from repro.models.module import materialize
    api = get_model(cfg)
    params = materialize(api.specs(cfg), jax.random.key(0))
    opt = steps_lib.default_optimizer(cfg)
    opt_state = jax.jit(opt.init)(params)
    it = synthetic_token_batches(4, 32, cfg.vocab_size)
    losses = []
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, m = built.jitted(params, opt_state, b, jnp.int32(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_serving_engine_generates():
    from repro.configs import get_config, smoke_config
    from repro.runtime.serving import Engine, ServeConfig
    cfg = smoke_config(get_config("rwkv6-3b"))
    eng = Engine(cfg, ServeConfig(batch_slots=2, max_seq=32))
    outs = eng.generate([[1, 2, 3], [4, 5]], max_new=6)
    assert all(len(o) == 6 for o in outs)
    assert eng.failed_requests == set()
    # greedy decoding is deterministic
    eng2 = Engine(cfg, ServeConfig(batch_slots=2, max_seq=32))
    outs2 = eng2.generate([[1, 2, 3], [4, 5]], max_new=6)
    assert outs == outs2


def test_serving_per_request_budget_fails_only_stuck_request():
    """Graceful degradation: a request exceeding its step budget is failed
    ALONE — partial output returned, slot freed — while every other
    request completes normally (no global serve-loop RuntimeError)."""
    from repro.configs import get_config, smoke_config
    from repro.runtime.serving import Engine, ServeConfig
    cfg = smoke_config(get_config("rwkv6-3b"))
    # budget 6: [4, 5] needs 2 prefill + 3 emit = 5 steps and completes;
    # the 8-token prompt exhausts its budget mid-prefill and is cut off
    eng = Engine(cfg, ServeConfig(batch_slots=2, max_seq=32,
                                  max_request_steps=6))
    outs = eng.generate([[1, 2, 3, 4, 5, 6, 7, 8], [4, 5]], max_new=3)
    assert eng.failed_requests == {0}
    assert len(outs[0]) < 3               # partial (here: still prefilling)
    assert len(outs[1]) == 3              # unaffected
    # the failed request matches the healthy engine's output prefix
    eng2 = Engine(cfg, ServeConfig(batch_slots=2, max_seq=32))
    outs2 = eng2.generate([[1, 2, 3, 4, 5, 6, 7, 8], [4, 5]], max_new=3)
    assert eng2.failed_requests == set()
    assert outs2[0][: len(outs[0])] == outs[0]
    assert outs2[1] == outs[1]
